"""Table II (cost & structure columns): all 8 topologies, both clusters.

Pure data: one scenario per Table II row (``registry.TABLE2_SPECS``), the
compute function derives everything from the spec's ``structure()`` view.
"""

from repro.core import registry as R
from repro.core import topology as T

from benchmarks import scenarios as S

SUITE = "table2_cost"

_PAPER = {"small": (T.PAPER_COSTS_SMALL, T.PAPER_DIAMETERS_SMALL),
          "large": (T.PAPER_COSTS_LARGE, T.PAPER_DIAMETERS_LARGE)}


def scenarios(ctx: S.RunContext) -> list[S.Scenario]:
    return [
        S.make(SUITE, f"{cluster}/{name}", topology=spec,
               cluster=cluster, table_row=name)
        for cluster, specs in R.TABLE2_SPECS.items()
        for name, spec in specs.items()
    ]


def compute(sc: S.Scenario, ctx: S.RunContext) -> list[dict]:
    cluster, name = sc.opts["cluster"], sc.opts["table_row"]
    tc = R.parse(sc.topology).structure()
    paper_costs, paper_diams = _PAPER[cluster]
    paper = paper_costs[name]
    return [{
        "cluster": cluster,
        "name": name,
        "cost_musd": round(tc.cost_musd, 2),
        "paper": paper,
        "err": f"{(tc.cost_musd - paper) / paper:+.1%}",
        "switches": tc.num_switches,
        "dac": tc.num_dac,
        "aoc": tc.num_aoc,
        "diam": tc.diameter,
        "paper_diam": paper_diams[name],
    }]
