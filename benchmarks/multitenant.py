"""multitenant quick suite: contention as a measured quantity (§III-E).

Two scenario groups on the unified time core:

* ``stripe/*`` — the adversarial co-placement experiment: two tenants
  interleaved by even/odd board columns, each looping a ring allreduce,
  priced in one joint steady-state waterfill (``netsim.replay``).  On
  HammingMesh both stripes are legal virtual sub-meshes with disjoint
  link sets, so each tenant's contention fraction (isolated / contended
  iteration time) stays ≈ 1.0; the same striping on a torus shares row
  links and the fraction collapses.  The summary asserts the acceptance
  bar ``hx2_isolation_holds``: every hx2 tenant ≥ 0.98, every torus
  tenant < 1.0.
* ``sched/*`` — the cluster scheduler with continuous replay on
  (contention series per job, Jain fairness over per-job fractions) and
  a priority/deadline trace under a preemption-enabled policy
  (preemptions, deadline miss rate, utilization).

Rows carry wall-clock timings so ``BENCH_multitenant.json`` can track
replay cost alongside the isolation result.
"""

import time

from repro import netsim as NS
from repro.cluster import POLICIES, SimConfig, poisson_trace, simulate
from repro.cluster.policies import GreedyPolicy
from repro.core import flowsim as F
from repro.core import registry as R

from benchmarks import scenarios as S

SUITE = "multitenant"

# (spec, board rows/cols the two stripes interleave over)
STRIPE_SPECS = (("hx2-16x16", 4, 8), ("torus-32x32", 4, 8))
STRIPE_COLL = "ring:s4MiB"
SCHED_SPEC = "hx2-8x8"
REPLAY_COLL = "ring:s1MiB"


def scenarios(ctx: S.RunContext) -> list[S.Scenario]:
    out = [
        S.make(SUITE, f"stripe/{spec}",
               scenario=f"{spec}/coll={STRIPE_COLL}", kind="stripe",
               rows=rows, cols=cols)
        for spec, rows, cols in STRIPE_SPECS
    ]
    out.append(S.make(SUITE, "sched/replay", topology=SCHED_SPEC,
                      kind="replay", seed=3))
    out.append(S.make(SUITE, "sched/preempt", topology=SCHED_SPEC,
                      kind="preempt", seed=3))
    return out


def _striped_schedules(net, rows: int, cols: int) -> tuple[dict, dict]:
    """Two tenants interleaved by even/odd board columns — adversarial for
    any fabric whose rows share links, harmless for HammingMesh."""
    scheds, sizes = {}, {}
    for tenant in (0, 1):
        boards = [(r, c) for r in range(rows)
                  for c in range(tenant, cols, 2)]
        eps = F.placement_endpoints(net, boards)
        scheds[tenant] = NS.schedule_for_endpoints(
            STRIPE_COLL, net, eps, group=str(tenant))
        sizes[tenant] = len(eps)
    return scheds, sizes


def _compute_stripe(sc: S.Scenario) -> list[dict]:
    net = sc.parsed().network()
    scheds, sizes = _striped_schedules(net, sc.opts["rows"], sc.opts["cols"])
    t0 = time.time()
    fr = NS.contention_fractions(net, scheds)
    wall = time.time() - t0
    return [
        {
            "kind": "stripe",
            "tenant": tenant,
            "endpoints": sizes[tenant],
            "contended_s": round(cont, 6),
            "isolated_s": round(iso, 6),
            "fraction": round(frac, 4),
            "wall_ms": round(wall * 1e3 / len(scheds), 1),
        }
        for tenant, (cont, iso, frac) in sorted(fr.items())
    ]


def _compute_replay(sc: S.Scenario) -> list[dict]:
    cfg = SimConfig.for_topology(sc.topology, seed=sc.seed,
                                 replay_collective=REPLAY_COLL)
    trace = poisson_trace(30, cfg.x, cfg.y, load=1.2, seed=sc.seed)
    t0 = time.time()
    res = simulate(trace, cfg, POLICIES["greedy"])
    wall = time.time() - t0
    s = res.summary()
    return [{
        "kind": "replay",
        "n_jobs": len(trace),
        "n_epochs": int(s["n_epochs"]),
        "contention_mean": round(s["contention_mean"], 4),
        "contention_min": round(s["contention_min"], 4),
        "jain_fairness": round(s["jain_fairness"], 4),
        "utilization": round(res.utilization(), 4),
        "wall_ms": round(wall * 1e3, 1),
    }]


def _compute_preempt(sc: S.Scenario) -> list[dict]:
    cfg = SimConfig.for_topology(sc.topology, seed=sc.seed)
    trace = poisson_trace(120, cfg.x, cfg.y, load=1.6, seed=sc.seed,
                          priorities=[(0, 0.8), (2, 0.2)],
                          deadline_slack=6.0)
    pol = GreedyPolicy(name="greedy-preempt", transpose=True,
                       sort_queue=True, backfill=True, preempt=True)
    t0 = time.time()
    res = simulate(trace, cfg, pol)
    wall = time.time() - t0
    s = res.summary()
    return [{
        "kind": "preempt",
        "n_jobs": len(trace),
        "n_preemptions": res.n_preemptions,
        "preempted_jobs": int(s["preempted_jobs"]),
        "deadline_miss_rate": round(s.get("deadline_miss_rate", 0.0), 4),
        "utilization": round(res.utilization(), 4),
        "wall_ms": round(wall * 1e3, 1),
    }]


def compute(sc: S.Scenario, ctx: S.RunContext) -> list[dict]:
    kind = sc.opts["kind"]
    if kind == "stripe":
        return _compute_stripe(sc)
    if kind == "replay":
        return _compute_replay(sc)
    return _compute_preempt(sc)


def summarize(results: list[tuple[S.Scenario, list[dict]]],
              ctx: S.RunContext) -> list[dict]:
    hx2 = [r["fraction"] for sc, out in results for r in out
           if r["kind"] == "stripe" and sc.topology.startswith("hx2")]
    torus = [r["fraction"] for sc, out in results for r in out
             if r["kind"] == "stripe" and sc.topology.startswith("torus")]
    rows = []
    if hx2 and torus:
        rows.append({
            "kind": "stripe",
            # the §III-E acceptance bar: sub-mesh tenants within 2% of
            # full isolation, the torus co-placement measurably below it
            "hx2_isolation_holds": (min(hx2) >= 0.98
                                    and max(torus) < 1.0),
            "hx2_min_fraction": min(hx2),
            "torus_max_fraction": max(torus),
        })
    return rows
