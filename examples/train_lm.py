"""End-to-end LM training example (~100M-class smoke model, few hundred steps).

  PYTHONPATH=src python examples/train_lm.py            # quick (50 steps)
  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import sys

from repro.launch import train

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "minicpm-2b-smoke"] + argv
    if not any(a.startswith("--steps") for a in argv):
        argv += ["--steps", "50"]
    sys.argv = [sys.argv[0]] + argv
    train.main()
