"""Drive the discrete-event cluster scheduler end to end.

Generates a Philly-style heavy-tailed trace, replays it on an Hx2Mesh
cluster under two policies (FIFO greedy vs sorted+backfill best-fit) with
board fail/repair churn and flow-level bandwidth probes, prints the summary
metrics, and round-trips the trace through the JSONL format.  A second
pass demos the unified-time-core additions: priority classes with
deadlines under a preemption-enabled policy, and continuous collective
replay turning per-job contention into a measured quantity.

Run:  PYTHONPATH=src python examples/cluster_scheduler.py
"""

import os
import statistics
import tempfile

from repro.cluster import (
    POLICIES,
    SimConfig,
    load_trace,
    philly_trace,
    save_trace,
    simulate,
)
from repro.cluster.policies import GreedyPolicy


def main() -> None:
    x = y = 8  # 64 boards, 256 accelerators (Hx2Mesh-8x8)
    trace = philly_trace(n_jobs=60, x=x, y=y, load=1.4, seed=7)
    horizon = max(j.arrival for j in trace)
    print(f"trace: {len(trace)} jobs over {horizon:.0f}s, "
          f"{sum(j.size for j in trace)} board-requests total")

    # replayable JSONL round-trip
    path = os.path.join(tempfile.mkdtemp(), "trace.jsonl")
    save_trace(trace, path)
    assert load_trace(path) == trace
    print(f"trace round-tripped through {path}")

    cfg = SimConfig(
        x, y,
        fail_rate_hz=4.0 / (x * y * horizon),  # ~4 board failures over the run
        repair_time_s=horizon / 10,
        probe_interval_s=horizon / 6,  # 6 flow-level bandwidth probes
        seed=0,
    )
    for policy_name in ("fifo", "best-fit"):
        res = simulate(trace, cfg, POLICIES[policy_name])
        s = res.summary()
        print(f"\npolicy={policy_name}")
        for key in ("utilization", "n_finished", "n_queued", "mean_wait_s",
                    "mean_slowdown", "n_failures", "n_repairs",
                    "mean_fragmentation"):
            if key in s:
                print(f"  {key:20s} {s[key]:.3f}")
        observed = [r for r in res.records.values() if r.achieved_bw_frac]
        if observed:
            alloc = statistics.mean(r.allocated_bw_frac for r in observed)
            ach = statistics.mean(
                statistics.mean(r.achieved_bw_frac) for r in observed)
            print(f"  {'allocated_bw_frac (mean)':20s} {alloc:.3f}")
            print(f"  {'achieved_bw_frac (mean)':20s} {ach:.3f}   "
                  f"({len(observed)} jobs probed)")

    # -- priorities + deadlines + preemption + measured contention --------
    hot = philly_trace(n_jobs=60, x=x, y=y, load=1.4, seed=7,
                       priorities=[(0, 0.8), (2, 0.2)], deadline_slack=6.0)
    cfg2 = SimConfig.for_topology(
        "hx2-8x8", seed=0, replay_collective="ring:s16MiB")
    pol = GreedyPolicy(name="greedy-preempt", transpose=True,
                       sort_queue=True, backfill=True, preempt=True)
    res = simulate(hot, cfg2, pol)
    s = res.summary()
    print("\npolicy=greedy-preempt (priorities 20% hot, deadlines 6x, "
          "replay=ring:s16MiB)")
    for key in ("utilization", "n_finished", "n_preemptions",
                "preempted_jobs", "deadline_miss_rate", "n_epochs",
                "contention_mean", "contention_min", "jain_fairness"):
        if key in s:
            print(f"  {key:20s} {s[key]:.3f}")
    frac = [(j, r.contention_fraction()) for j, r in res.records.items()
            if r.contention_fraction() is not None]
    worst = min(frac, key=lambda kv: kv[1])
    print(f"  worst contention: jid {worst[0]} at {worst[1]:.3f} over "
          f"{len(res.records[worst[0]].iter_samples)} fabric epochs")


if __name__ == "__main__":
    main()
