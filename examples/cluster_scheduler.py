"""Drive the discrete-event cluster scheduler end to end.

Generates a Philly-style heavy-tailed trace, replays it on an Hx2Mesh
cluster under two policies (FIFO greedy vs sorted+backfill best-fit) with
board fail/repair churn and flow-level bandwidth probes, prints the summary
metrics, and round-trips the trace through the JSONL format.

Run:  PYTHONPATH=src python examples/cluster_scheduler.py
"""

import os
import statistics
import tempfile

from repro.cluster import (
    POLICIES,
    SimConfig,
    load_trace,
    philly_trace,
    save_trace,
    simulate,
)


def main() -> None:
    x = y = 8  # 64 boards, 256 accelerators (Hx2Mesh-8x8)
    trace = philly_trace(n_jobs=60, x=x, y=y, load=1.4, seed=7)
    horizon = max(j.arrival for j in trace)
    print(f"trace: {len(trace)} jobs over {horizon:.0f}s, "
          f"{sum(j.size for j in trace)} board-requests total")

    # replayable JSONL round-trip
    path = os.path.join(tempfile.mkdtemp(), "trace.jsonl")
    save_trace(trace, path)
    assert load_trace(path) == trace
    print(f"trace round-tripped through {path}")

    cfg = SimConfig(
        x, y,
        fail_rate=4.0 / (x * y * horizon),  # ~4 board failures over the run
        repair_time=horizon / 10,
        probe_interval=horizon / 6,  # 6 flow-level bandwidth probes
        seed=0,
    )
    for policy_name in ("fifo", "best-fit"):
        res = simulate(trace, cfg, POLICIES[policy_name])
        s = res.summary()
        print(f"\npolicy={policy_name}")
        for key in ("utilization", "n_finished", "n_queued", "mean_wait_s",
                    "mean_slowdown", "n_failures", "n_repairs",
                    "mean_fragmentation"):
            if key in s:
                print(f"  {key:20s} {s[key]:.3f}")
        observed = [r for r in res.records.values() if r.achieved_bw]
        if observed:
            alloc = statistics.mean(r.allocated_bw for r in observed)
            ach = statistics.mean(
                statistics.mean(r.achieved_bw) for r in observed)
            print(f"  {'allocated_bw (mean)':20s} {alloc:.3f}")
            print(f"  {'achieved_bw (mean)':20s} {ach:.3f}   "
                  f"({len(observed)} jobs probed)")


if __name__ == "__main__":
    main()
