"""The paper's fault-tolerance loop: train -> board failure -> allocator remap
-> checkpoint restore -> continue (paper §III-E / §IV-A).

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import sys
import tempfile

from repro.launch import train

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        sys.argv = [sys.argv[0], "--arch", "llama3.2-3b-smoke", "--steps", "40",
                    "--checkpoint-dir", d, "--checkpoint-every", "10",
                    "--simulate-failure", "25"]
        train.main()
