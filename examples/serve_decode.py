"""Batched-decoding example over the SSM arch (constant-memory state).

  PYTHONPATH=src python examples/serve_decode.py
"""

import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "mamba2-130m-smoke",
                "--batch", "4", "--prompt-len", "16", "--decode", "32"]
    serve.main()
