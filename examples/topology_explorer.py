"""Explore topologies by spec string (the unified topology API).

Pass any registry specs on the command line; with no arguments, sweep the
HxMesh design space around 1k accelerators (the cost / global-bandwidth /
flexibility trade-off of paper Fig 1) against a fat-tree baseline.

  PYTHONPATH=src python examples/topology_explorer.py
  PYTHONPATH=src python examples/topology_explorer.py hx4-8x8 torus-32x32 ft1024
"""

import sys

from repro.core.registry import parse
from repro.core.topology import HxMesh

HEADER = (f"{'spec':16s} {'topology':20s} {'accels':>7s} {'cost M$':>8s} "
          f"{'$/accel':>8s} {'bisect':>7s} {'diam':>5s} {'boards':>7s}")


def describe(spec: str) -> str:
    t = parse(spec)
    tc = t.structure()
    alloc = t.allocator()
    boards = f"{alloc.x}x{alloc.y}" if alloc is not None else "-"
    return (f"{t.spec:16s} {tc.name:20s} {tc.num_accelerators:7d} "
            f"{tc.cost_musd:8.1f} {tc.cost / tc.num_accelerators:8.0f} "
            f"{tc.bisection_fraction:7.3f} {tc.diameter:5d} {boards:>7s}")


def default_sweep() -> list[str]:
    """HxMesh board-size x global-size sweep around 1k accelerators."""
    specs = ["ft1024"]
    for a in (1, 2, 4, 8):
        for x in (32, 16, 8, 4):
            if 900 <= HxMesh(a, a, x, x).num_accelerators <= 1100:
                specs.append(f"hx{a}-{x}x{x}")
    return specs


def main(argv: list[str]) -> None:
    specs = argv or default_sweep()
    print(HEADER)
    for spec in specs:
        try:
            print(describe(spec))
        except ValueError as e:
            print(f"{spec:16s} ERROR: {e}")
    if not argv:
        print("\nTapering the global trees (paper §III-F) scales the cost of "
              "the switched layer by the taper factor while rings stay "
              "full-bandwidth.")


if __name__ == "__main__":
    main(sys.argv[1:])
