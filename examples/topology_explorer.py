"""Sweep the HxMesh design space (board size x global size): the cost /
global-bandwidth / flexibility trade-off of paper Fig 1.

  PYTHONPATH=src python examples/topology_explorer.py
"""

from repro.core.topology import HxMesh, FatTree

print(f"{'topology':20s} {'accels':>7s} {'cost M$':>8s} {'$/accel':>8s} "
      f"{'bisect':>7s} {'diam':>5s}")
ft = FatTree(1024, 0.0).structure()
print(f"{'nonblocking FT':20s} {ft.num_accelerators:7d} {ft.cost_musd:8.1f} "
      f"{ft.cost/ft.num_accelerators:8.0f} {ft.bisection_fraction:7.2f} {ft.diameter:5d}")
for a in (1, 2, 4, 8):
    for x in (32, 16, 8, 4):
        hx = HxMesh(a, a, x, x)
        if not 900 <= hx.num_accelerators <= 1100:
            continue
        tc = hx.structure()
        print(f"{tc.name:20s} {tc.num_accelerators:7d} {tc.cost_musd:8.1f} "
              f"{tc.cost/tc.num_accelerators:8.0f} {tc.bisection_fraction:7.3f} "
              f"{tc.diameter:5d}")
print("\nTapering the global trees (paper §III-F) scales the cost of the "
      "switched layer by the taper factor while rings stay full-bandwidth.")
