"""Explore topologies — and full scenarios — by string (the unified API).

Pass registry *topology specs* or full *scenario strings* on the command
line.  A bare spec prints the structural row (cost / bisection /
diameter); a scenario string (``topology/traffic[/fail=...]``) also runs
the flow-level engine and prints the measured achievable fraction under
the scenario's failure set next to the healthy baseline — the Fig-10
degradation story from one CLI token.  A ``fidelity=packet`` (or
``fidelity=calibrated``) leg runs the cycle-level engine and prints the
fluid and packet numbers side by side — the congestion penalty the fluid
tier cannot see.  With no arguments, sweep the HxMesh design space
around 1k accelerators (the cost / global-bandwidth / flexibility
trade-off of paper Fig 1) against a fat-tree baseline.

``--trace DIR`` additionally records each simulated scenario (a
``coll=`` or ``fidelity=packet`` leg) as a Chrome trace-event file
under DIR and prints a Perfetto walkthrough: open
https://ui.perfetto.dev, drag the ``.trace.json`` in, and read one
process per engine — collective phases as spans on their group tracks,
the per-waterfill ``link_util`` / ``active_flows`` counters under
``netsim``, VOQ occupancy milestones under ``packetsim``.  Tracing is
measurement-only: the numbers printed are byte-identical with and
without ``--trace`` (DESIGN.md §13).

  PYTHONPATH=src python examples/topology_explorer.py
  PYTHONPATH=src python examples/topology_explorer.py hx4-8x8 torus-32x32
  PYTHONPATH=src python examples/topology_explorer.py \\
      hx2-8x8/alltoall/fail=boards:4:seed7 \\
      hx2-8x8/skewed-alltoall:h8:seed3 \\
      torus-16x16/bisection/fail=links:1%:seed1 \\
      torus-6x6/alltoall/fidelity=packet \\
      torus-32x32/alltoall/fidelity=calibrated
  PYTHONPATH=src python examples/topology_explorer.py --trace out \\
      hx2-8x8/coll=ring:s64MiB torus-4x4/alltoall/fidelity=packet
"""

import dataclasses
import os
import sys

from repro.core.registry import parse, parse_scenario
from repro.core.topology import HxMesh
from repro.packetsim import FidelitySpec

HEADER = (f"{'spec':16s} {'topology':20s} {'accels':>7s} {'cost M$':>8s} "
          f"{'$/accel':>8s} {'bisect':>7s} {'diam':>5s} {'boards':>7s}")


def describe(spec: str) -> str:
    t = parse(spec)
    tc = t.structure()
    alloc = t.allocator()
    boards = f"{alloc.x}x{alloc.y}" if alloc is not None else "-"
    return (f"{t.spec:16s} {tc.name:20s} {tc.num_accelerators:7d} "
            f"{tc.cost_musd:8.1f} {tc.cost / tc.num_accelerators:8.0f} "
            f"{tc.bisection_fraction:7.3f} {tc.diameter:5d} {boards:>7s}")


def describe_scenario(token: str) -> str:
    """Measured achievable fraction of a full scenario vs its healthy
    baseline (same topology + traffic, failure leg dropped); a ``coll=``
    leg additionally reports the time-domain simulated completion.  A
    non-fluid ``fidelity=`` leg prints the fluid number next to the
    packet/calibrated one, side by side."""
    sc = parse_scenario(token)
    frac = sc.fraction()
    label = "measured" if sc.fidelity.mode == "fluid" else sc.fidelity.mode
    line = f"{sc}: {label} {sc.traffic} = {frac:.4f}"
    if sc.fidelity:
        fluid = dataclasses.replace(sc, fidelity=FidelitySpec()).fraction()
        ratio = fluid / frac if frac else float("inf")
        line += f"  (fluid {fluid:.4f}, penalty {ratio:.3f}x)"
    if sc.failures:
        healthy = dataclasses.replace(
            sc, failures=type(sc.failures)()).fraction()
        loss = 0.0 if healthy == 0 else (healthy - frac) / healthy
        line += (f"  (healthy {healthy:.4f}, degradation {loss:+.1%} "
                 f"under {sc.failures})")
    # time-domain completion: always for a coll= leg; for a bare traffic
    # leg only at packet fidelity (small fabrics — a one-shot demand
    # schedule at scale would swamp the fluid engine with O(n^2) flows)
    if sc.collective is not None or sc.fidelity.mode == "packet":
        t = sc.completion_time()
        what = sc.collective if sc.collective is not None else sc.traffic
        line += f"\n  {what}: {label} completion {t * 1e3:.3f} ms"
        if sc.fidelity:
            fluid_sc = dataclasses.replace(sc, fidelity=FidelitySpec())
            if fluid_sc.collective is not None:
                fluid_t = fluid_sc.completion_time()
            else:  # one-shot traffic schedule, fluid engine directly
                from repro.core import commodel as C
                from repro.netsim import demand_schedule, simulate_schedule

                net = fluid_sc.network()
                fluid_t = simulate_schedule(
                    net, demand_schedule(net, fluid_sc.traffic.demand(net),
                                         name=str(fluid_sc.traffic)),
                    link_bps=C.LINK_BPS).time
            line += f" (fluid {fluid_t * 1e3:.3f} ms, {t / fluid_t:.2f}x)"
        elif sc.failures:
            healthy_t = parse_scenario(
                f"{sc.topology}/{sc.collective}").completion_time()
            line += (f" (healthy {healthy_t * 1e3:.3f} ms, "
                     f"{t / healthy_t:.2f}x)")
        if sc.collective is not None:
            model = sc.collective.model_time(sc.topology.num_accelerators)
            if model is not None:
                line += f"; alpha-beta model {model * 1e3:.3f} ms"
    return line


def default_sweep() -> list[str]:
    """HxMesh board-size x global-size sweep around 1k accelerators."""
    specs = ["ft1024"]
    for a in (1, 2, 4, 8):
        for x in (32, 16, 8, 4):
            if 900 <= HxMesh(a, a, x, x).num_accelerators <= 1100:
                specs.append(f"hx{a}-{x}x{x}")
    return specs


def trace_scenario(token: str, trace_dir: str) -> None:
    """Re-run one simulated scenario under a tracer and export the
    Chrome trace-event file (the printed numbers already shown are
    unchanged — tracing is measurement-only)."""
    from repro.obs import Tracer

    sc = parse_scenario(token)
    if sc.collective is None and sc.fidelity.mode != "packet":
        return  # nothing time-domain to trace for this token
    stem = str(sc).replace("/", "__").replace(":", "-").replace("=", "-")
    tracer = Tracer(name=stem, out_dir=trace_dir)
    sc.completion_time(trace=tracer)
    path = tracer.export(os.path.join(trace_dir, f"{stem}.trace.json"))
    counters = tracer.metrics.to_dict()["counters"]
    print(f"  trace -> {path} ({len(tracer.events)} events; "
          f"counters: {', '.join(f'{k}={v:g}' for k, v in counters.items())})")
    print("  open https://ui.perfetto.dev and drag the file in: one "
          "process per engine, phases as spans, per-waterfill link_util "
          "counters")


def main(argv: list[str]) -> None:
    trace_dir = None
    if "--trace" in argv:
        i = argv.index("--trace")
        if i + 1 >= len(argv):
            sys.exit("--trace needs a directory argument")
        trace_dir = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    structural = [s for s in argv if "/" not in s]
    scenario_tokens = [s for s in argv if "/" in s]
    if structural or not argv:
        print(HEADER)
        for spec in structural or default_sweep():
            try:
                print(describe(spec))
            except ValueError as e:
                print(f"{spec:16s} ERROR: {e}")
    for token in scenario_tokens:
        try:
            print(describe_scenario(token))
            if trace_dir:
                trace_scenario(token, trace_dir)
        except ValueError as e:
            print(f"{token}: ERROR: {e}")
    if not argv:
        print("\nTapering the global trees (paper §III-F) scales the cost of "
              "the switched layer by the taper factor while rings stay "
              "full-bandwidth.\nScenario strings work too, e.g. "
              "hx2-8x8/alltoall/fail=boards:4:seed7")


if __name__ == "__main__":
    main(sys.argv[1:])
