"""Quickstart: the paper's pieces in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

# 1. HammingMesh topology analytics (paper §III, Table II) ------------------
from repro.core.topology import HxMesh, FatTree

hx = HxMesh(a=2, b=2, x=16, y=16)          # 1,024-accelerator Hx2Mesh
ft = FatTree(1024, taper=0.0)
print(f"Hx2Mesh: {hx.num_accelerators} accels, cost ${hx.structure().cost_musd:.1f}M, "
      f"bisection {hx.bisection_fraction:.2f}, diameter {hx.diameter}")
print(f"nonblocking fat tree costs ${ft.structure().cost_musd:.1f}M "
      f"({ft.structure().cost / hx.structure().cost:.1f}x more)")

# 2. Job allocation with failures (paper §IV) --------------------------------
from repro.core.allocation import HxMeshAllocator, Job

alloc = HxMeshAllocator(16, 16)
alloc.fail_board(3, 5)
pl = alloc.allocate(Job(0, 4, 4), transpose=True)
print(f"4x4 job -> virtual sub-HxMesh rows={pl.rows[:4]} cols={pl.cols[:4]}")

# 3. The paper's collective algorithms as shard_map programs -----------------
from repro.core.commodel import best_algorithm

for size in (1e5, 1e9):
    name, t = best_algorithm(p=64, size_bytes=size)
    print(f"allreduce of {size:.0e} B on 64 devices -> {name} ({t*1e6:.0f} us)")

# 4. Train a tiny model through the full stack -------------------------------
from repro.configs import get_config
from repro.data.pipeline import make_batch
from repro.parallel.sharding import Policy
from repro.train import optimizer as opt, steps

cfg = get_config("llama3.2-3b-smoke")
from repro.models import get_model

model = get_model(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
step = jax.jit(steps.make_train_step(cfg, ocfg, steps.TrainOptions(remat=False),
                                     Policy()))
ostate = opt.init(params)
for s in range(20):
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 4, step=s).items()}
    params, ostate, m = step(params, ostate, batch)
    if s % 5 == 4:
        print(f"step {s+1:2d}  loss {float(m['loss']):.3f}")
print("quickstart OK")
